"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the original SSD CUDA kernel splits work over
SMs with a separate inter-chunk scan kernel. On TPU the grid executes
*sequentially* over the innermost dimension, so the inter-chunk recurrence
folds into the same kernel: the running state (P, N) lives in VMEM scratch
that persists across the chunk grid dimension — a single fused pass, no
second kernel and no HBM round-trip for the states.

Per (batch, head, chunk) tile:
  intra-chunk  : (C @ B^T) ⊙ L  then  @ x      — two MXU matmuls
  inter-chunk  : C @ state                      — one MXU matmul
  state update : state*exp(cum_last) + (x⊙decay)^T @ B

Tile sizes: chunk × N and chunk × P with chunk=128..256, N=128, P=64 — all
MXU-aligned. B/C are group-shared across heads (Mamba2 GQA analogue); the
index_map folds head -> group, so no replication materializes in HBM.

Inputs are pre-scaled by the wrapper (`ops.ssd_scan`): xdt = x*dt,
dta = dt * a (a = -exp(a_log)) — elementwise prep stays in XLA where it
fuses with the upstream projections.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, dta_ref, b_ref, c_ref, y_ref, fin_ref, state_scr, *,
                n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dta = dta_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    bt = b_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    ct = c_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    q = xdt.shape[0]

    cum = jnp.cumsum(dta)                              # (Q,)
    # L[i, j] = exp(cum_i - cum_j), i >= j  (1-semiseparable mask)
    li = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(rows >= cols, jnp.exp(li), 0.0)

    scores = jnp.dot(ct, bt.T, preferred_element_type=jnp.float32) * L
    y = jnp.dot(scores, xdt, preferred_element_type=jnp.float32)   # (Q, P)

    state = state_scr[...]                             # (P, N)
    # inter-chunk: y += exp(cum) * (C @ state^T)
    y = y + jnp.exp(cum)[:, None] * jnp.dot(ct, state.T,
                                            preferred_element_type=jnp.float32)

    decay_to_end = jnp.exp(cum[-1] - cum)              # (Q,)
    state_new = state * jnp.exp(cum[-1]) + jnp.dot(
        (xdt * decay_to_end[:, None]).T, bt, preferred_element_type=jnp.float32)
    state_scr[...] = state_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        fin_ref[0, 0] = state_new.astype(fin_ref.dtype)


def ssd_scan(xdt: jnp.ndarray, dta: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
             *, chunk: int = 128, interpret: bool = False,
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused SSD scan.

    xdt: (batch, S, H, P)  dt-weighted inputs (x * dt)
    dta: (batch, S, H)     log-decays (dt * a, a negative)
    B:   (batch, S, G, N), C: (batch, S, G, N), G | H.
    Returns (y (batch,S,H,P) fp32, final_state (batch,H,P,N) fp32).
    S must be a multiple of `chunk` (wrapper pads).
    """
    bsz, s, h, p = xdt.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    assert h % g == 0, (h, g)
    rep = h // g
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdt, dta, B, C)
    return y, fin
