"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention — blockwise online-softmax attention (GQA, causal, sliding)
ssd_scan        — Mamba2 SSD fused chunked scan (state carried in VMEM)
skewed_bucket   — paper Algorithm 1 skewed hash partitioner (shuffle/MoE)

``ops`` holds the jit wrappers (model layouts, CPU interpret fallback);
``ref`` holds the pure-jnp oracles used by the allclose test sweeps.
"""
from repro.kernels import ops, ref  # noqa: F401
