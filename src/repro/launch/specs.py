"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — what the dry-run lowers
against. Train cells produce (TrainState abstract, batch specs); prefill
cells produce (params abstract, prompt specs); decode cells produce
(params abstract, decode-state abstract, token specs).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchBundle, ModelConfig, ShapeConfig
from repro.models.model import init_decode_state, init_params
from repro.runtime.train_loop import train_state_init

Pytree = Any


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for a full-sequence pass (train / prefill)."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "vision":
        from repro.models.frontends import frontend_feature_dim
        specs["input_embeds"] = _sds((b, s, frontend_feature_dim(cfg)), jnp.float32)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = _sds((b, s), jnp.int32)
    if cfg.encoder_layers > 0:
        from repro.models.frontends import frontend_feature_dim
        specs["enc_feats"] = _sds((b, cfg.max_source_positions,
                                   frontend_feature_dim(cfg)), jnp.float32)
    return specs


def params_abstract(cfg: ModelConfig) -> Pytree:
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def train_state_abstract(cfg: ModelConfig, bundle: ArchBundle) -> Pytree:
    return jax.eval_shape(lambda k: train_state_init(k, cfg, bundle),
                          jax.random.PRNGKey(0))


def decode_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV budget for a decode cell: the shape's seq_len capped at the arch's
    architectural max (whisper's decoder caps at 448 target positions —
    recorded in DESIGN.md §5)."""
    return min(shape.seq_len, cfg.max_seq_len)


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 ) -> Tuple[Pytree, jax.ShapeDtypeStruct, Optional[jax.ShapeDtypeStruct]]:
    """(decode-state abstract, token spec, enc_out spec or None)."""
    b = shape.global_batch
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, b, decode_cache_len(cfg, shape)))
    tok = _sds((b,), jnp.int32)
    enc = None
    if cfg.encoder_layers > 0:
        enc = _sds((b, cfg.max_source_positions, cfg.d_model), jnp.bfloat16)
    return state, tok, enc


def input_specs(cfg: ModelConfig, bundle: ArchBundle, shape: ShapeConfig,
                ) -> Dict[str, Any]:
    """Everything the dry-run needs for one cell, keyed by role."""
    if shape.kind == "train":
        return {"state": train_state_abstract(cfg, bundle),
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params_abstract(cfg),
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "decode":
        state, tok, enc = decode_specs(cfg, shape)
        return {"params": params_abstract(cfg), "dstate": state,
                "token": tok, "enc_out": enc}
    raise ValueError(shape.kind)
