import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The first two lines above MUST run before any jax import — jax locks the
device count at first init. Do not set that flag globally (smoke tests and
benches must see 1 device).

Per cell this driver:
  1. builds the production mesh ((16,16) or (2,16,16)),
  2. builds ShapeDtypeStruct stand-ins (launch.specs.input_specs),
  3. builds shardings (runtime.sharding) with divisibility fallbacks,
  4. jit(...).lower(...).compile()  — failure = a sharding bug in this repo,
  5. records memory_analysis / cost_analysis / loop-adjusted HLO cost +
     roofline terms into artifacts/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun                         # full sweep
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh multi
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_bundle
from repro.configs.shapes import ALL_SHAPES, SHAPES, shape_skip_reason
from repro.launch import specs as specs_mod
from repro.launch.hlo_cost import parse_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import compute_roofline, improvement_hint
from repro.models.model import decode_step, prefill
from repro.runtime.sharding import (
    ShardingReport, batch_shardings, cache_shardings,
    make_activation_constraint, param_shardings, train_state_shardings,
)
from repro.runtime.train_loop import make_train_step

MESHES = {"single": dict(multi_pod=False), "multi": dict(multi_pod=True)}


def _mem_dict(ma) -> Dict[str, float]:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               overrides: Optional[Dict[str, Any]] = None):
    """Returns (lowered, compiled, context dict). Raises on failure."""
    bundle = get_bundle(arch)
    if overrides:
        import dataclasses
        overrides = dict(overrides)
        ssm_chunk = overrides.pop("ssm_chunk", None)
        model = bundle.model
        if ssm_chunk is not None:
            model = dataclasses.replace(
                model, ssm=dataclasses.replace(model.ssm, chunk=ssm_chunk))
        bundle = bundle.replace(
            model=model,
            mesh=dataclasses.replace(bundle.mesh, **overrides))
    cfg = bundle.model
    shape = SHAPES[shape_name]
    skip = shape_skip_reason(cfg, shape)
    if skip:
        return None, None, {"skip": skip}
    mesh = make_production_mesh(**MESHES[mesh_kind])
    n_chips = mesh.devices.size
    pod_size = 256 if mesh_kind == "multi" else None
    report = ShardingReport()
    cell = specs_mod.input_specs(cfg, bundle, shape)

    if shape.kind == "train":
        constrain = make_activation_constraint(
            mesh, bundle.mesh, shape.global_batch, shape.seq_len)
        step = make_train_step(cfg, bundle, constrain=constrain)
        st_sh = train_state_shardings(cfg, mesh, bundle.mesh, cell["state"],
                                      report)
        b_sh = batch_shardings(cfg, mesh, bundle.mesh, cell["batch"])
        lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None),
                          donate_argnums=(0,)).lower(cell["state"],
                                                     cell["batch"])
    elif shape.kind == "prefill":
        max_len = specs_mod.decode_cache_len(cfg, shape)

        def prefill_step(params, batch):
            return prefill(params, batch.get("tokens"), cfg, max_len,
                           enc_feats=batch.get("enc_feats"),
                           input_embeds=batch.get("input_embeds"),
                           remat=bundle.mesh.remat)

        p_sh = param_shardings(cfg, mesh, bundle.mesh, report)
        b_sh = batch_shardings(cfg, mesh, bundle.mesh, cell["batch"])
        lowered = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                          ).lower(cell["params"], cell["batch"])
    else:  # decode
        def serve_step(params, dstate, token, enc_out=None):
            logits, new_state = decode_step(params, dstate, token, cfg,
                                            enc_out=enc_out)
            return jnp.argmax(logits, -1).astype(jnp.int32), new_state

        p_sh = param_shardings(cfg, mesh, bundle.mesh, report)
        c_sh = cache_shardings(cfg, mesh, bundle.mesh, cell["dstate"],
                               shape.global_batch, report)
        tok_spec = batch_shardings(cfg, mesh, bundle.mesh,
                                   {"t": cell["token"]})["t"]
        args = [cell["params"], cell["dstate"], cell["token"]]
        in_sh = [p_sh, c_sh, tok_spec]
        if cell["enc_out"] is not None:
            args.append(cell["enc_out"])
            in_sh.append(batch_shardings(cfg, mesh, bundle.mesh,
                                         {"e": cell["enc_out"]})["e"])
        lowered = jax.jit(serve_step, in_shardings=tuple(in_sh),
                          donate_argnums=(1,)).lower(*args)

    ctx = {"bundle": bundle, "cfg": cfg, "shape": shape, "mesh": mesh,
           "n_chips": n_chips, "pod_size": pod_size,
           "fallbacks": report.fallbacks}
    return lowered, ctx


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             overrides: Optional[Dict[str, Any]] = None,
             tag: str = "") -> Dict[str, Any]:
    # real lowering/compile wall time for the dry-run report — host
    # tooling measurement, not simulation state
    t0 = time.time()  # hemt-lint: disable=HL003
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "tag": tag}
    try:
        res = lower_cell(arch, shape_name, mesh_kind, overrides)
        if res[0] is None:
            rec["status"] = "skipped"
            rec["reason"] = res[-1]["skip"]
            return _write(rec, out_dir)
        lowered, ctx = res
        t_lower = time.time() - t0  # hemt-lint: disable=HL003  (compile timing)
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower  # hemt-lint: disable=HL003  (compile timing)

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        cost = parse_hlo(compiled.as_text(), pod_size=ctx["pod_size"])
        ici_bytes = cost.collective_operand_bytes - cost.dcn_operand_bytes
        roof = compute_roofline(
            ctx["cfg"], ctx["shape"], n_chips=ctx["n_chips"],
            hlo_flops=cost.flops, hlo_bytes=cost.bytes_accessed,
            ici_bytes=ici_bytes, dcn_bytes=cost.dcn_operand_bytes)

        rec.update({
            "status": "ok",
            "n_chips": ctx["n_chips"],
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory_analysis": _mem_dict(ma),
            "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                                  if isinstance(v, (int, float))},
            "hlo_cost": cost.summary(),
            "collectives": [
                {"kind": c.kind, "bytes": c.operand_bytes,
                 "group": c.group_size, "dcn": c.pod_crossing,
                 "count": c.count} for c in cost.collectives],
            "roofline": roof.as_dict(),
            "hint": improvement_hint(roof),
            "sharding_fallbacks": ctx["fallbacks"],
        })
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return _write(rec, out_dir)


def _write(rec: Dict[str, Any], out_dir: str) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    line = f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:7s} {status:8s}"
    if status == "ok":
        r = rec["roofline"]
        mb = rec["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        line += (f" compile={rec['compile_s']:6.1f}s"
                 f" args={rec['memory_analysis'].get('argument_size_in_bytes', 0)/1e9:7.2f}GB"
                 f" temp={mb:7.2f}GB"
                 f" c/m/coll={r['compute_s']:.3f}/{r['memory_s']:.3f}/"
                 f"{r['collective_s']:.3f}s -> {r['bottleneck']}")
    elif status == "skipped":
        line += f" ({rec['reason'][:60]})"
    else:
        line += f" {rec['error'][:90]}"
    print(line, flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in ALL_SHAPES] + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                rec = run_cell(arch, shape, mesh, args.out, tag=args.tag)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
