"""Training CLI — HeMT-DP end-to-end driver.

CPU-runnable on any `--arch` via `--reduced` (the same code path a TPU
fleet runs; slice heterogeneity comes from calibrated speed profiles).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --reduced \
      --steps 20 --mode hemt --slices 1.0,0.4 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import ARCH_IDS, get_bundle, get_reduced
from repro.checkpoint import CheckpointManager
from repro.runtime.hemt_driver import HeMTTrainer, SliceSpec
from repro.runtime.train_loop import train_state_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mode", default="hemt",
                    choices=["hemt", "homt", "static-even"])
    ap.add_argument("--slices", default="1.0,0.4",
                    help="comma-separated relative slice speeds")
    ap.add_argument("--grain-batch", type=int, default=2)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    bundle = get_bundle(args.arch)
    bundle = bundle.replace(
        model=cfg,
        train=dataclasses.replace(bundle.train, lr=args.lr,
                                  total_steps=max(args.steps, 10),
                                  warmup_steps=max(args.steps // 10, 1)))

    speeds = [float(s) for s in args.slices.split(",")]
    slices = [SliceSpec(f"slice{i}", [(0.0, v)], grain_overhead=0.05)
              for i, v in enumerate(speeds)]

    trainer = HeMTTrainer(cfg, bundle, slices, grain_batch=args.grain_batch,
                          global_batch=args.global_batch,
                          seq_len=args.seq_len, mode=args.mode,
                          seed=args.seed)
    state = train_state_init(jax.random.PRNGKey(args.seed), cfg, bundle)

    mgr = CheckpointManager(args.ckpt) if args.ckpt else None
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest(state)
        if restored is not None:
            start, state, _ = restored
            print(f"resumed from step {start}")

    for _ in range(args.steps - start):
        state, rep = trainer.run_step(state)
        print(json.dumps({
            "step": rep.step, "loss": round(rep.loss, 4),
            "makespan_s": round(rep.makespan, 2),
            "idle_s": round(rep.idle_time, 2),
            "grains": rep.grain_counts}), flush=True)
        if mgr is not None and (rep.step + 1) % args.ckpt_every == 0:
            mgr.save_async(rep.step + 1, state)
    if mgr is not None:
        mgr.wait()
        mgr.save(args.steps, state)
    print(f"total fleet time {trainer.total_time():.1f}s  "
          f"mean barrier idle {trainer.mean_idle():.2f}s  mode={args.mode}")


if __name__ == "__main__":
    main()
