"""Roofline terms from dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs / peak_FLOPs            [per-device program]
    memory term     = HLO_bytes / HBM_bw
    collective term = ICI collective bytes / ICI_bw  +  DCN bytes / DCN_bw

HLO_* come from `hlo_cost.parse_hlo` over the *compiled, partitioned*
per-device program (loop trip counts folded in — XLA's cost_analysis does
not do this), so terms are per-device seconds for one step. The brief's
"/(chips × bw)" normalization is equivalent: our parser already reads the
per-chip program, i.e. global_bytes/chips.

Hardware constants (v5e): 197 TFLOP/s bf16; 819 GB/s HBM; ICI ~50 GB/s per
link x 2 usable links for ring collectives = 100 GB/s effective per chip;
DCN 25 GB/s per host / 4 chips = 6.25 GB/s per chip (multi-pod axis only).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, active_param_count

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 100e9               # 2 x 50 GB/s links usable per ring direction
DCN_BW = 6.25e9              # per-chip share of 25 GB/s host DCN


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dcn_s: float
    model_flops_per_dev: float
    hlo_flops: float
    bottleneck: str
    useful_ratio: float      # MODEL_FLOPS / HLO_FLOPs

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dcn_s": self.dcn_s,
            "bottleneck": self.bottleneck,
            "model_flops_per_dev": self.model_flops_per_dev,
            "hlo_flops_per_dev": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS for the whole cell (all chips).

    train:   6 * N_active * tokens      (fwd + bwd)
    prefill: 2 * N_active * tokens      (fwd only)
    decode:  2 * N_active * batch       (one new token per sequence)
    """
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def compute_roofline(cfg: ModelConfig, shape: ShapeConfig, *, n_chips: int,
                     hlo_flops: float, hlo_bytes: float,
                     ici_bytes: float, dcn_bytes: float) -> Roofline:
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    ici_s = ici_bytes / ICI_BW
    dcn_s = dcn_bytes / DCN_BW
    collective_s = ici_s + dcn_s
    mf = model_flops(cfg, shape) / n_chips
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(compute_s, memory_s, collective_s, dcn_s, mf, hlo_flops,
                    bottleneck, mf / hlo_flops if hlo_flops else math.inf)


def improvement_hint(r: Roofline) -> str:
    if r.bottleneck == "compute":
        if r.useful_ratio < 0.6:
            return ("compute-bound with low useful ratio: cut remat recompute "
                    "or fuse the attention/router side computations")
        return "compute-bound near useful peak: only kernel-level wins remain"
    if r.bottleneck == "memory":
        return ("memory-bound: shrink materialized intermediates (remat "
                "policy, fp32->bf16 temps, sequence-parallel saved carries, "
                "fused loss)")
    return ("collective-bound: re-shard to shorten the all-reduce (FSDP "
            "prefix on data axis), overlap grad all-reduce with backward, "
            "or compress the DCN (pod-axis) reduction")
