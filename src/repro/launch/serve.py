"""Serving CLI — HeMT continuous batching across heterogeneous replicas.

Two paths share one batcher:

* the **demo loop** (default) serves a reduced model on N simulated
  replicas (one optionally throttled, the paper's contended-host case)
  and compares HeMT capacity-proportional dispatch vs even dispatch on
  batch completion times;
* ``--simulate`` runs the **fleet scenario**: an open-loop arrival trace
  (:mod:`repro.core.arrivals`) through the resident calendar
  (:mod:`repro.runtime.serving`) — no model, no jax — and reports
  p50/p99 latency, SLO attainment and goodput for the chosen batching
  mode.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \\
      --replicas 1.0,1.0,0.4 --rounds 8 --requests 24

  PYTHONPATH=src python -m repro.launch.serve --simulate \\
      --replicas 2.0,1.5,1.0,0.5 --trace poisson --rate 2.5 \\
      --horizon 120 --window 2 --slo 4 --mode hemt
"""
from __future__ import annotations

import argparse
import json


def _simulate(args) -> None:
    from repro.core.arrivals import DiurnalTrace, MMPPTrace, PoissonTrace
    from repro.core.faults import FaultTrace, SpotPreemption
    from repro.core.simulator import SimNode
    from repro.runtime.serving import RequestModel, ServingScenario

    speeds = [float(s) for s in args.replicas.split(",")]
    nodes = []
    for i, s in enumerate(speeds):
        if args.throttle_at > 0.0 and i == 0:
            # burstable replica: full speed until the credits run out
            nodes.append(SimNode(
                f"rep{i}",
                [(0.0, s), (args.throttle_at, s * args.throttle_to)],
                args.overhead))
        else:
            nodes.append(SimNode(f"rep{i}", [(0.0, s)], args.overhead))
    if args.trace == "poisson":
        trace = PoissonTrace(args.rate, args.horizon, seed=args.seed)
    elif args.trace == "diurnal":
        trace = DiurnalTrace(args.rate * 0.4, args.rate * 1.6,
                             args.horizon / 2.0, args.horizon,
                             seed=args.seed)
    else:
        trace = MMPPTrace((args.rate * 0.5, args.rate * 3.0),
                          (args.horizon / 6.0, args.horizon / 18.0),
                          args.horizon, seed=args.seed)
    faults = None
    if args.preempt_at > 0.0:
        faults = FaultTrace((SpotPreemption(
            node=len(nodes) - 1, at=args.preempt_at,
            warning=args.preempt_drain),))
    scenario = ServingScenario(
        nodes, window=args.window, mode=args.mode, slo=args.slo,
        uplink_bw=args.uplink_bw if args.prefill_mb > 0.0 else None,
        model=RequestModel(decode_work=args.decode_work,
                           work_cv=args.work_cv,
                           prefill_mb=args.prefill_mb, seed=args.seed),
        faults=faults)
    report = scenario.run(trace)
    print(json.dumps({
        "trace": args.trace, "mode": args.mode,
        "replicas": speeds, "window_s": args.window,
        "slo_s": args.slo,
        **{k: round(v, 6) for k, v in report.summary().items()},
    }, indent=2), flush=True)


def _demo(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models.model import init_decode_state, init_params
    from repro.runtime.serve_loop import HeMTBatcher, make_serve_step

    cfg = get_reduced(args.arch)
    if cfg.encoder_layers > 0 or cfg.frontend != "none":
        raise SystemExit("serve demo targets decoder-only archs")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    serve_step = jax.jit(make_serve_step(cfg),
                         static_argnames=())

    speeds = [float(s) for s in args.replicas.split(",")]
    names = [f"rep{i}" for i in range(len(speeds))]
    batcher = HeMTBatcher(names, mode=args.mode,
                          min_share=args.min_share)

    for rnd in range(args.rounds):
        shares = batcher.dispatch(args.requests)
        finish = {}
        for name, speed in zip(names, speeds):
            b = shares[name]
            if b == 0:
                finish[name] = 0.0
                continue
            # real decode of b requests for gen_len tokens
            state = init_decode_state(cfg, b, args.gen_len + 1)
            tok = jnp.ones((b,), jnp.int32)
            for _ in range(args.gen_len):
                tok, _logits, state = serve_step(params, state, tok)
            # virtual wall time: tokens / (speed * base token rate)
            tokens = b * args.gen_len
            finish[name] = tokens / (speed * 100.0)
            batcher.observe(name, tokens, finish[name])
        makespan = max(finish.values())
        idle = makespan - min(v for v in finish.values() if v > 0)
        print(json.dumps({"round": rnd, "shares": shares,
                          "makespan_s": round(makespan, 3),
                          "idle_s": round(idle, 3)}), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--replicas", default="1.0,1.0,0.4",
                    help="comma-separated relative replica speeds")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24,
                    help="requests per dispatch round (demo loop)")
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mode", default="hemt",
                    choices=["hemt", "even", "oracle"])
    ap.add_argument("--min-share", type=int, default=1,
                    help="per-replica dispatch floor (demo loop)")
    ap.add_argument("--seed", type=int, default=0)
    # fleet simulation
    ap.add_argument("--simulate", action="store_true",
                    help="run an open-loop arrival trace through the "
                         "resident calendar instead of the demo loop")
    ap.add_argument("--trace", default="poisson",
                    choices=["poisson", "diurnal", "mmpp"])
    ap.add_argument("--rate", type=float, default=2.5,
                    help="mean arrival rate, requests/s")
    ap.add_argument("--horizon", type=float, default=120.0)
    ap.add_argument("--window", type=float, default=2.0,
                    help="batching window, seconds")
    ap.add_argument("--slo", type=float, default=4.0)
    ap.add_argument("--decode-work", type=float, default=1.0)
    ap.add_argument("--work-cv", type=float, default=0.0)
    ap.add_argument("--prefill-mb", type=float, default=0.0)
    ap.add_argument("--uplink-bw", type=float, default=50.0)
    ap.add_argument("--overhead", type=float, default=0.01)
    ap.add_argument("--throttle-at", type=float, default=0.0,
                    help="exhaust replica 0's burst credits at this time")
    ap.add_argument("--throttle-to", type=float, default=0.3,
                    help="post-exhaustion speed fraction for replica 0")
    ap.add_argument("--preempt-at", type=float, default=0.0,
                    help="spot-preempt the last replica at this time")
    ap.add_argument("--preempt-drain", type=float, default=0.0)
    args = ap.parse_args()

    if args.simulate:
        _simulate(args)
    else:
        if args.mode == "oracle":
            raise SystemExit("oracle mode exists only under --simulate")
        _demo(args)


if __name__ == "__main__":
    main()
