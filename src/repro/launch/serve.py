"""Serving CLI — HeMT continuous batching across heterogeneous replicas.

Serves a reduced model on N simulated replicas (one optionally throttled,
the paper's contended-host case) and compares HeMT capacity-proportional
dispatch vs even dispatch on batch completion times.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --replicas 1.0,1.0,0.4 --rounds 8 --requests 24
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced
from repro.models.model import init_decode_state, init_params
from repro.runtime.serve_loop import HeMTBatcher, make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ARCH_IDS)
    ap.add_argument("--replicas", default="1.0,1.0,0.4")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24,
                    help="requests per dispatch round")
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mode", default="hemt", choices=["hemt", "even"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if cfg.encoder_layers > 0 or cfg.frontend != "none":
        raise SystemExit("serve demo targets decoder-only archs")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    serve_step = jax.jit(make_serve_step(cfg),
                         static_argnames=())

    speeds = [float(s) for s in args.replicas.split(",")]
    names = [f"rep{i}" for i in range(len(speeds))]
    batcher = HeMTBatcher(names, mode=args.mode)

    for rnd in range(args.rounds):
        shares = batcher.dispatch(args.requests)
        finish = {}
        for name, speed in zip(names, speeds):
            b = shares[name]
            if b == 0:
                finish[name] = 0.0
                continue
            # real decode of b requests for gen_len tokens
            state = init_decode_state(cfg, b, args.gen_len + 1)
            tok = jnp.ones((b,), jnp.int32)
            for _ in range(args.gen_len):
                tok, _logits, state = serve_step(params, state, tok)
            # virtual wall time: tokens / (speed * base token rate)
            tokens = b * args.gen_len
            finish[name] = tokens / (speed * 100.0)
            batcher.observe(name, tokens, finish[name])
        makespan = max(finish.values())
        idle = makespan - min(v for v in finish.values() if v > 0)
        print(json.dumps({"round": rnd, "shares": shares,
                          "makespan_s": round(makespan, 3),
                          "idle_s": round(idle, 3)}), flush=True)


if __name__ == "__main__":
    main()
