"""Post-partitioning HLO cost model.

XLA's ``compiled.cost_analysis()`` does NOT multiply while-loop bodies by
their trip counts, so a scan-over-layers program under-reports FLOPs by
~n_layers x. This parser walks the optimized HLO text, builds the call
graph (while bodies x known_trip_count, fusions, to_apply), and computes:

  * flops            — 2*M*N*K per dot (batch dims included), loop-adjusted
  * bytes            — Σ (operand + output bytes) of top-level instructions
                       per computation (a fusion = one kernel: its operands
                       + outputs approximate its HBM traffic), loop-adjusted
  * collectives      — per-op: kind, operand/output bytes, replica-group
                       size, pod-crossing flag (from iota replica_groups),
                       loop-adjusted totals

This is a structural cost model of the *compiled per-device program* — the
profile the §Roofline/§Perf methodology iterates on (no real-TPU clock in
this container).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "custom-call", "copy-start",
                   "copy-done", "while", "conditional", "call"}

_SHAPE_RE = re.compile(r"(pred|s4|u4|s8|u8|s16|u16|f16|bf16|s32|u32|f32|"
                       r"s64|u64|f64|c64|c128|token)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_NAME_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+")


def _parse_instr(line: str) -> Optional["Instr"]:
    """Hand parser: `%name = TYPE opcode(OPERANDS), attrs...` where TYPE may
    be a tuple containing `/*index=N*/` comments (so no regex over it)."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    # type: balanced-paren tuple or a single token
    if i < n and line[i] == "(":
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i:j + 1]
        i = j + 1
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        type_str = line[i:j]
        i = j
    while i < n and line[i] == " ":
        i += 1
    j = i
    while j < n and (line[j].isalnum() or line[j] in "-_"):
        j += 1
    op = line[i:j]
    if j >= n or line[j] != "(":
        return None
    return Instr(name, type_str, op, line[j + 1:])


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str          # everything after the opening paren of operands

    def operands(self) -> List[str]:
        depth, out, cur = 0, [], []
        for ch in self.rest:
            if ch == ")" and depth == 0:
                break
            if ch in "({[":
                depth += 1
            elif ch in ")}]":
                depth -= 1
            cur.append(ch)
        src = "".join(cur)
        return re.findall(r"%([\w\.\-]+)", src)

    def attrs(self) -> str:
        depth = 0
        for i, ch in enumerate(self.rest):
            if ch == ")" and depth == 0:
                return self.rest[i + 1:]
            if ch in "({[":
                depth += 1
            elif ch in ")}]":
                depth -= 1
        return ""


@dataclass
class CollectiveRecord:
    kind: str
    operand_bytes: int
    output_bytes: int
    group_size: int
    pod_crossing: bool
    count: float = 1.0      # loop-adjusted occurrence count


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: List[CollectiveRecord] = field(default_factory=list)

    @property
    def collective_operand_bytes(self) -> float:
        return sum(c.operand_bytes * c.count for c in self.collectives)

    @property
    def dcn_operand_bytes(self) -> float:
        return sum(c.operand_bytes * c.count for c in self.collectives
                   if c.pod_crossing)

    def summary(self) -> Dict[str, float]:
        return {"flops": self.flops, "bytes": self.bytes_accessed,
                "collective_bytes": self.collective_operand_bytes,
                "dcn_bytes": self.dcn_operand_bytes,
                "n_collectives": sum(c.count for c in self.collectives)}


def _parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if not line.startswith(" ") and ("->" in line) and "{" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            comps[cur].append(ins)
    return comps


def _iota_groups(attr: str) -> Optional[np.ndarray]:
    """Parse `replica_groups=[G,S]<=[r0,r1,..](T(perm))?` into an (G,S) id
    array; explicit `{{0,1},{2,3}}` also handled. None if absent."""
    m = re.search(r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\]"
                  r"(?:T\(([0-9,]+)\))?", attr)
    if m:
        out_dims = [int(x) for x in m.group(1).split(",")]
        reshape = [int(x) for x in m.group(2).split(",")]
        ids = np.arange(int(np.prod(reshape)))
        if m.group(3):
            perm = [int(x) for x in m.group(3).split(",")]
            ids = ids.reshape(reshape).transpose(perm).reshape(-1)
        return ids.reshape(out_dims)
    m = re.search(r"replica_groups=\{(\{[0-9, ]+\}(?:,\{[0-9, ]+\})*)\}", attr)
    if m:
        rows = re.findall(r"\{([0-9, ]+)\}", m.group(1))
        groups = [[int(x) for x in r.replace(" ", "").split(",")] for r in rows]
        width = max(len(g) for g in groups)
        if all(len(g) == width for g in groups):
            return np.asarray(groups)
        return None
    return None


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    ops = instr.operands()
    if len(ops) < 2:
        return 0.0
    lhs_t, rhs_t = symtab.get(ops[0]), symtab.get(ops[1])
    if lhs_t is None or rhs_t is None:
        return 0.0
    lhs, rhs = _shape_dims(lhs_t), _shape_dims(rhs_t)
    attrs = instr.attrs()

    def dims(key):
        m = re.search(key + r"=\{([0-9,]*)\}", attrs)
        if not m or not m.group(1):
            return []
        return [int(x) for x in m.group(1).split(",")]

    lc = dims("lhs_contracting_dims")
    lb = dims("lhs_batch_dims")
    rc = dims("rhs_contracting_dims")
    rb = dims("rhs_batch_dims")
    batch = math.prod(lhs[d] for d in lb) if lb else 1
    contract = math.prod(lhs[d] for d in lc) if lc else 1
    m_dim = math.prod(lhs[d] for d in range(len(lhs))
                      if d not in lc and d not in lb)
    n_dim = math.prod(rhs[d] for d in range(len(rhs))
                      if d not in rc and d not in rb)
    return 2.0 * batch * m_dim * n_dim * contract


def parse_hlo(text: str, *, pod_size: Optional[int] = None) -> HloCost:
    """pod_size: devices per pod (e.g. 256 for the (2,16,16) mesh); a
    collective is pod-crossing if any replica group spans pods."""
    comps = _parse_computations(text)
    symtabs = {c: {i.name: i.type_str for i in instrs}
               for c, instrs in comps.items()}

    # references: comp -> list of (callee, multiplier, kind)
    refs: Dict[str, List[Tuple[str, float, str]]] = defaultdict(list)
    for cname, instrs in comps.items():
        for ins in instrs:
            attrs = ins.attrs()
            if ins.op == "while":
                m = re.search(r'known_trip_count[":{]+n[":]+(\d+)', attrs)
                trip = float(m.group(1)) if m else 1.0
                for key in ("body", "condition"):
                    mm = re.search(key + r"=%([\w\.\-]+)", attrs)
                    if mm:
                        refs[cname].append((mm.group(1), trip, "while"))
            else:
                for key in ("calls", "to_apply"):
                    mm = re.search(key + r"=%([\w\.\-]+)", attrs)
                    if mm:
                        kind = "fusion" if ins.op == "fusion" else "call"
                        refs[cname].append((mm.group(1), 1.0, kind))
                mm = re.search(r"branch_computations=\{([^}]*)\}", attrs)
                if mm:
                    for b in re.findall(r"%([\w\.\-]+)", mm.group(1)):
                        refs[cname].append((b, 1.0, "branch"))

    # in-place fusion classification: does the called computation update a
    # slice of an aliased buffer (scan-carry stacking) or rewrite it fully?
    _has_dus: Dict[str, bool] = {}
    for cname, instrs in comps.items():
        _has_dus[cname] = any(i.op == "dynamic-update-slice" for i in instrs)

    # local costs per computation
    local_flops: Dict[str, float] = {}
    local_bytes: Dict[str, float] = {}
    local_colls: Dict[str, List[CollectiveRecord]] = {}
    for cname, instrs in comps.items():
        fl, by = 0.0, 0.0
        colls: List[CollectiveRecord] = []
        st = symtabs[cname]
        for ins in instrs:
            if ins.op in ("dot", "convolution"):
                fl += _dot_flops(ins, st)
            base_op = ins.op.replace("-start", "")
            if base_op in _COLLECTIVES:
                obytes = sum(_type_bytes(st.get(o, "")) for o in ins.operands())
                groups = _iota_groups(ins.attrs())
                gsize = int(groups.shape[-1]) if groups is not None else 0
                crossing = False
                if pod_size and groups is not None:
                    crossing = bool(np.any(groups // pod_size
                                           != groups[..., :1] // pod_size))
                colls.append(CollectiveRecord(
                    base_op, obytes, _type_bytes(ins.type_str), gsize, crossing))
            if ins.op not in _SKIP_BYTES_OPS and not ins.op.endswith("-done"):
                out_b = _type_bytes(ins.type_str)
                op_bytes = [_type_bytes(st.get(o, "")) for o in ins.operands()]
                if ins.op == "dynamic-slice":
                    # reads only the slice it produces, not the whole input
                    by += 2 * out_b
                elif ins.op == "dynamic-update-slice":
                    # in-place: writes the update region only
                    upd = op_bytes[1] if len(op_bytes) > 1 else out_b
                    by += 2 * upd
                elif ins.op == "fusion" and out_b in op_bytes:
                    # XLA aliases an operand buffer for the output. Two
                    # patterns: a DUS-root fusion touches only the update
                    # region; an elementwise in-place fusion reads+writes
                    # the full buffer once.
                    rest = list(op_bytes)
                    rest.remove(out_b)
                    mm = re.search(r"calls=%([\w\.\-]+)", ins.attrs())
                    if mm and _has_dus.get(mm.group(1), False):
                        by += 2 * sum(rest)
                    else:
                        by += 2 * out_b + sum(rest)
                else:
                    by += out_b + sum(op_bytes)
        local_flops[cname] = fl
        local_bytes[cname] = by
        local_colls[cname] = colls

    # totals via memoized DFS (flops traverse fusions; bytes do not —
    # a fusion is one kernel whose HBM traffic is its operands + output)
    memo_f: Dict[str, float] = {}
    memo_b: Dict[str, float] = {}
    memo_c: Dict[str, List[CollectiveRecord]] = {}

    def total(cname: str) -> Tuple[float, float, List[CollectiveRecord]]:
        if cname in memo_f:
            return memo_f[cname], memo_b[cname], memo_c[cname]
        memo_f[cname] = 0.0  # cycle guard
        memo_b[cname] = 0.0
        memo_c[cname] = []
        fl = local_flops.get(cname, 0.0)
        by = local_bytes.get(cname, 0.0)
        cl = [CollectiveRecord(c.kind, c.operand_bytes, c.output_bytes,
                               c.group_size, c.pod_crossing, c.count)
              for c in local_colls.get(cname, [])]
        for callee, mult, kind in refs.get(cname, []):
            cf, cb, cc = total(callee)
            fl += mult * cf
            if kind != "fusion":
                by += mult * cb
            for c in cc:
                cl.append(CollectiveRecord(c.kind, c.operand_bytes,
                                           c.output_bytes, c.group_size,
                                           c.pod_crossing, c.count * mult))
        memo_f[cname], memo_b[cname], memo_c[cname] = fl, by, cl
        return fl, by, cl

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line[len("ENTRY"):].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: computation with most instructions
        entry = max(comps, key=lambda c: len(comps[c]))
    fl, by, cl = total(entry)
    return HloCost(flops=fl, bytes_accessed=by, collectives=cl)
