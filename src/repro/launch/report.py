"""Render the dry-run/roofline markdown tables from artifacts/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report artifacts/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

from repro.configs import ARCH_IDS
from repro.configs.shapes import ALL_SHAPES


def load(dirname: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_gb(b: float) -> str:
    return f"{b / 1e9:.2f}"


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    lines = [
        "| arch | shape | status | compile s | args GB/dev | temp GB/dev |"
        " HLO GFLOPs/dev | HLO GB/dev | coll GB/dev (DCN) | #colls |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(ARCH_IDS)}
    sorder = {s.name: i for i, s in enumerate(ALL_SHAPES)}
    for r in sorted([r for r in recs if r["mesh"] == mesh and not r.get("tag")],
                    key=lambda r: (order[r["arch"]], sorder[r["shape"]])):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — "
                         f"| — | — | — | — |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — "
                         f"| — | — | — | — |")
            continue
        ma, hc = r["memory_analysis"], r["hlo_cost"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} "
            f"| {_fmt_gb(ma.get('argument_size_in_bytes', 0))} "
            f"| {_fmt_gb(ma.get('temp_size_in_bytes', 0))} "
            f"| {hc['flops'] / 1e9:,.0f} | {_fmt_gb(hc['bytes'])} "
            f"| {_fmt_gb(hc['collective_bytes'])} "
            f"({_fmt_gb(hc['dcn_bytes'])}) | {hc['n_collectives']:.0f} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict], mesh: str) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s (DCN s) |"
        " bottleneck | useful ratio | next move |",
        "|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(ARCH_IDS)}
    sorder = {s.name: i for i, s in enumerate(ALL_SHAPES)}
    for r in sorted([r for r in recs if r["mesh"] == mesh and not r.get("tag")],
                    key=lambda r: (order[r["arch"]], sorder[r["shape"]])):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped(full-attn) | — | — |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR "
                         f"| — | — |")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3f} "
            f"| {ro['memory_s']:.3f} | {ro['collective_s']:.3f} "
            f"({ro['dcn_s']:.3f}) | {ro['bottleneck']} "
            f"| {min(ro['useful_ratio'], 99.0):.2f} | {r['hint'][:72]} |")
    return "\n".join(lines)


def summary(recs: List[Dict]) -> str:
    base = [r for r in recs if not r.get("tag")]
    n_ok = sum(r["status"] == "ok" for r in base)
    n_skip = sum(r["status"] == "skipped" for r in base)
    n_err = sum(r["status"] == "error" for r in base)
    return (f"{len(base)} cells: {n_ok} ok, {n_skip} skipped "
            f"(documented long_500k full-attention skips), {n_err} errors")


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    recs = load(d)
    print("## Summary\n")
    print(summary(recs) + "\n")
    for mesh in ("single", "multi"):
        print(f"\n## Dry-run — {mesh} "
              f"({'2x16x16=512' if mesh == 'multi' else '16x16=256'} chips)\n")
        print(dryrun_table(recs, mesh))
    print("\n## Roofline — single pod (16x16)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline — multi-pod (2x16x16)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
