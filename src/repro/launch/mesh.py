"""Production meshes.

Defined as FUNCTIONS, not module-level constants, so importing this module
never touches jax device state (the dry-run must set
--xla_force_host_platform_device_count *before* first jax init).
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:                     # older jax: Auto is the only behavior
    def _axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods =
    512 chips as (pod=2, data=16, model=16) — the `pod` axis is pure data
    parallelism over DCN (HeMT-DP skews grain counts along it)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(shape)))


def make_host_mesh():
    """1-device mesh with the production axis names — lets smoke tests run
    the exact same sharded code paths on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_kw(2))
