"""Cluster state — the Mesos-analogue resource layer (paper Fig 6).

The paper extends Mesos RPC messages with executor-speed fields so the
application framework (Spark) can skew its partitions. Here the launcher
keeps `ClusterState`: per-slice chip counts, HeMT speed estimates and
heartbeat liveness; `offers()` is the resource-offer the planner consumes,
and `report()` is the per-step feedback going the other way — the two
arrows of the paper's Fig 6 information exchange.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.estimators import ARSpeedEstimator
from repro.runtime.ft import FleetMonitor, Heartbeat


@dataclass
class SliceInfo:
    name: str
    chips: int
    preemptible: bool = False     # spot/burstable-style capacity
    speed: Optional[float] = None  # latest HeMT estimate (None = cold)


@dataclass
class ResourceOffer:
    """What the cluster manager offers the application framework."""
    slices: List[SliceInfo]
    at: float


class ClusterState:
    def __init__(self, slices: Sequence[SliceInfo], *, alpha: float = 0.3,
                 heartbeat_timeout: float = 3.0):
        self.slices: Dict[str, SliceInfo] = {s.name: s for s in slices}
        self.estimator = ARSpeedEstimator(alpha=alpha)
        self.monitor = FleetMonitor(list(self.slices),
                                    timeout=heartbeat_timeout)
        self.clock = 0.0

    # -- framework-facing (paper Fig 6: manager -> framework) -------------
    def offers(self) -> ResourceOffer:
        alive = self.monitor.alive()
        for name in alive:
            self.slices[name].speed = self.estimator.speed(name)
        return ResourceOffer([self.slices[n] for n in alive], self.clock)

    # -- runtime-facing (framework -> manager) -----------------------------
    def report(self, slice_name: str, grains_done: int, elapsed: float,
               now: Optional[float] = None) -> None:
        self.clock = now if now is not None else self.clock + elapsed
        self.monitor.heartbeat(Heartbeat(slice_name, self.clock,
                                         grains_done, elapsed))
        if grains_done > 0 and elapsed > 0:
            self.estimator.observe(slice_name, grains_done, elapsed)

    def check(self) -> List[str]:
        """Advance liveness checks; returns newly-dead slice names."""
        dead, _stragglers = self.monitor.check(self.clock)
        return dead

    # -- elasticity ---------------------------------------------------------
    def add_slice(self, info: SliceInfo) -> None:
        self.slices[info.name] = info
        self.monitor.add(info.name, self.clock)

    def remove_slice(self, name: str) -> None:
        self.slices.pop(name, None)
        self.monitor.remove(name)
        self.estimator.forget(name)
