"""Paper §6.2 on the training fleet: burstable (token-bucket) slices.

Three slices with different initial CPU-credit balances (the paper's
t2-style instances). The a-priori plan comes from the superposed
workload-vs-time curves W_i(t) (paper Figs 10-12, exact worked example in
`repro.core.capacity`); the online AR(1) planner then tracks the slices as
their credits deplete mid-run — the case where static provisioning lies
and only online HeMT stays balanced.

  PYTHONPATH=src python examples/burstable_hemt.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ArchBundle, TrainConfig, get_reduced
from repro.core.capacity import BurstableNode, burstable_split
from repro.core.simulator import SimNode
from repro.runtime.hemt_driver import HeMTTrainer, SliceSpec
from repro.runtime.train_loop import train_state_init

STEPS = 14


def main() -> None:
    cfg = dataclasses.replace(get_reduced("granite-3-8b"), n_layers=2)
    bundle = ArchBundle(model=cfg, train=TrainConfig(
        lr=1e-3, warmup_steps=2, total_steps=STEPS))

    # paper-style fleet: credits deplete at different times under load
    bnodes = {"credit_rich": BurstableNode(credits=120.0, baseline=0.4),
              "credit_low": BurstableNode(credits=30.0, baseline=0.4),
              "depleted": BurstableNode(credits=0.0, baseline=0.4)}
    print("a-priori burstable split of 8 grains (superposed W_i(t), Fig 12):")
    shares, t_star = burstable_split(list(bnodes.values()), 8.0)
    for (name, _), s in zip(bnodes.items(), shares):
        print(f"  {name:12s} {s:.2f} grains")
    print(f"  common finish t' = {t_star:.2f}\n")

    slices = [SliceSpec(name, SimNode.burstable(name, bn).profile, 0.05)
              for name, bn in bnodes.items()]
    tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=16,
                     seq_len=32, mode="hemt", alpha=0.2, grain_cost=4.0)
    state = train_state_init(jax.random.PRNGKey(0), cfg, bundle)
    for _ in range(STEPS):
        state, rep = tr.run_step(state)
        print(f"step {rep.step:3d} loss {rep.loss:7.4f} "
              f"makespan {rep.makespan:6.1f}s idle {rep.idle_time:5.1f}s "
              f"grains {rep.grain_counts}")
    print(f"\nThe planner tracks credit depletion online: the credit_low "
          f"slice's share shrinks once its bucket empties (compare early vs "
          f"late 'grains'). Mean barrier idle {tr.mean_idle():.2f}s.")


if __name__ == "__main__":
    main()
