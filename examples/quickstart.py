"""Quickstart: end-to-end HeMT-DP training with checkpoint/restart.

Trains a decoder LM on the deterministic synthetic corpus across a
heterogeneous two-slice fleet (one slice at 0.4x — a contended or
burstable pod), with the paper's OA-HeMT planner sizing per-slice
macrotasks (grain counts) each step. Interference is injected mid-run to
show live re-skewing, and training is killed + resumed from the latest
checkpoint to show fault tolerance.

  PYTHONPATH=src python examples/quickstart.py                  # ~2 min CPU
  PYTHONPATH=src python examples/quickstart.py --preset 100m    # the
      deployable recipe (~110M params, few hundred steps) — sized for a
      real slice, not for this CPU container.
"""
import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ArchBundle, TrainConfig, get_reduced
from repro.configs.base import AttentionConfig, ModelConfig
from repro.checkpoint import CheckpointManager
from repro.runtime.hemt_driver import HeMTTrainer, SliceSpec
from repro.runtime.train_loop import train_state_init

PRESETS = {
    # tiny: CPU-friendly demo (~1.1M params)
    "tiny": dict(d_model=128, n_layers=4, d_ff=384, vocab=2048, heads=4,
                 steps=30, global_batch=16, grain_batch=2, seq=64),
    # 100m: the brief's end-to-end driver recipe (~110M params)
    "100m": dict(d_model=768, n_layers=12, d_ff=2304, vocab=32_768, heads=12,
                 steps=300, global_batch=64, grain_batch=8, seq=512),
}


def build_config(p) -> ModelConfig:
    return ModelConfig(
        name=f"quickstart-{p['d_model']}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], d_ff=p["d_ff"],
        vocab_size=p["vocab"],
        attention=AttentionConfig(n_heads=p["heads"], n_kv_heads=p["heads"],
                                  head_dim=p["d_model"] // p["heads"]),
        tie_embeddings=True, max_seq_len=p["seq"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = build_config(p)
    bundle = ArchBundle(model=cfg, train=TrainConfig(
        lr=3e-3, warmup_steps=max(p["steps"] // 10, 2),
        total_steps=p["steps"]))

    # fleet: slice1 runs at 0.4x; slice0 degrades to 0.5x mid-run
    half = p["steps"] // 2
    slices = [
        SliceSpec("slice0", [(0.0, 1.0), (half * 10.0, 0.5)], 0.05),
        SliceSpec("slice1", [(0.0, 0.4)], 0.05),
    ]
    trainer = HeMTTrainer(cfg, bundle, slices, grain_batch=p["grain_batch"],
                          global_batch=p["global_batch"], seq_len=p["seq"],
                          mode="hemt", grain_cost=1.0)
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="quickstart_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=2)

    state = train_state_init(jax.random.PRNGKey(0), cfg, bundle)
    restored = mgr.restore_latest(state)
    if restored:
        start, state, _ = restored
        print(f"[resume] from step {start}")

    kill_at = int(p["steps"] * 0.6)
    crashed = False
    for i in range(p["steps"]):
        state, rep = trainer.run_step(state)
        if rep.step % 5 == 0 or rep.step == p["steps"] - 1:
            print(f"step {rep.step:4d} loss {rep.loss:7.4f} "
                  f"makespan {rep.makespan:6.2f}s idle {rep.idle_time:5.2f}s "
                  f"grains {rep.grain_counts}")
        if rep.step % 10 == 9:
            mgr.save_async(rep.step + 1, state)
        if rep.step >= kill_at and not crashed and not restored:
            crashed = True
            mgr.wait()
            print(f"[fault] simulating crash at step {rep.step}; "
                  f"resuming from latest checkpoint {mgr.latest()}")
            _step0, state, _ = mgr.restore_latest(state)
            # planner estimates survive in-process; on a real restart they
            # re-learn within ~2 steps (paper Fig 8)
    mgr.wait()
    mgr.save(p["steps"], state)
    print(f"done: total fleet time {trainer.total_time():.1f}s, "
          f"mean barrier idle {trainer.mean_idle():.2f}s, "
          f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
