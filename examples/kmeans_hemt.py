"""Paper §7 / Fig 17: K-Means under HeMT vs HomT vs Spark-default even
partitioning, on two executors provisioned at 1.0 and 0.4 cores.

Real JAX math (centroids identical across modes — scheduling never changes
results); completion times from the calibrated executor model.

  PYTHONPATH=src python examples/kmeans_hemt.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.simulator import SimNode
from repro.workloads.kmeans import KMeansJob, kmeans_reference

ITERS = 30
K = 8


def main() -> None:
    rng = np.random.default_rng(0)
    # 4 well-separated blobs + noise, 2 GB-ish scaled down
    centers = rng.normal(scale=6.0, size=(K, 8))
    pts = np.concatenate([
        centers[i] + rng.normal(size=(400, 8)) for i in range(K)])
    rng.shuffle(pts)

    nodes = lambda: [SimNode.constant("full-core", 1.0, overhead=0.2),
                     SimNode.constant("0.4-core", 0.4, overhead=0.2)]
    ref = kmeans_reference(pts, K, ITERS)

    print(f"{'mode':<12} {'finish_s':>9} {'mean_idle_s':>12} {'centroid_err':>13}")
    results = {}
    for mode, kw in (("hemt", {"weights": [1.0, 0.4]}),
                     ("even", {}),
                     ("homt-8", {"n_tasks": 8}),
                     ("homt-32", {"n_tasks": 32})):
        job = KMeansJob(pts, K, nodes(), mode=mode.split("-")[0], work_per_point=2e-3, **kw)
        cent = job.run(ITERS)
        err = float(np.max(np.abs(np.asarray(cent) - ref)))
        idle = np.mean([r.idle for r in job.reports])
        results[mode] = job.total_time()
        print(f"{mode:<12} {job.total_time():9.1f} {idle:12.2f} {err:13.1e}")

    gain = (results["even"] - results["hemt"]) / results["even"] * 100
    print(f"\nHeMT vs default even partitioning: {gain:.1f}% faster "
          f"(paper reports ~10% for realistic workloads)")


if __name__ == "__main__":
    main()
