"""Fleet serving: an open-loop diurnal trace through the resident
calendar, HeMT vs even batching on tail latency.

A four-replica fleet (4:3:2:1 speeds, the fastest one burstable — its
CPU credits run out mid-trace) takes a sinusoidal diurnal arrival
stream.  Every 2 s window becomes one resident batch job; the HeMT
policy sizes each batch's decode split from the shared AR(1) estimator,
the even policy is the HomT-like baseline.  No model, no jax — this is
the pure scheduling claim at trace scale.

  PYTHONPATH=src python examples/fleet_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.arrivals import DiurnalTrace
from repro.core.simulator import SimNode
from repro.runtime.serving import RequestModel, ServingScenario

TRACE = DiurnalTrace(base_rate=1.0, peak_rate=4.0, period=60.0,
                     horizon=120.0, seed=11)
SPEEDS = (2.0, 1.5, 1.0, 0.5)
THROTTLE_AT, THROTTLE_TO = 40.0, 0.6      # replica 0's credit cliff


def fleet():
    nodes = [SimNode("n0", [(0.0, SPEEDS[0]), (THROTTLE_AT, THROTTLE_TO)],
                     0.01)]
    nodes += [SimNode(f"n{i}", [(0.0, s)], 0.01)
              for i, s in enumerate(SPEEDS[1:], start=1)]
    return nodes


def main() -> None:
    print(f"diurnal trace: ~{TRACE.expected():.0f} requests over "
          f"{TRACE.horizon:.0f}s (rate {TRACE.base_rate}-{TRACE.peak_rate}"
          "/s), replica n0 throttles "
          f"{SPEEDS[0]}x -> {THROTTLE_TO}x at t={THROTTLE_AT:.0f}s\n")
    for mode in ("even", "hemt"):
        scenario = ServingScenario(fleet(), window=2.0, mode=mode,
                                   slo=5.0, model=RequestModel(seed=7))
        rep = scenario.run(TRACE)
        s = rep.summary()
        print(f"{mode:>5}: p50={s['p50_s']:.2f}s p99={s['p99_s']:.2f}s "
              f"SLO(5s) attainment={s['attainment']:.1%} "
              f"goodput={s['goodput_rps']:.2f} req/s")


if __name__ == "__main__":
    main()
