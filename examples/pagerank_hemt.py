"""Paper §7 / Fig 18: PageRank (100 short iterations) with Algorithm 1's
skewed hash partitioner vs the default even hash vs HomT microtasks.

PageRank's iterations are short (~10s at 2-way in the paper), so per-task
scheduling overhead bites: 64-way microtasking loses badly — exactly the
paper's Fig 18 story.

  PYTHONPATH=src python examples/pagerank_hemt.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.simulator import SimNode
from repro.workloads.pagerank import PageRankJob, pagerank_reference, random_graph

ITERS = 100
N = 20_000


def main() -> None:
    src, dst = random_graph(N, 5, seed=1)
    nodes = lambda: [SimNode.constant("full-core", 1.0, overhead=0.15),
                     SimNode.constant("0.4-core", 0.4, overhead=0.15)]
    ref = pagerank_reference(src, dst, N, iters=ITERS)

    print(f"{'mode':<12} {'finish_s':>9} {'owned_vertices':>18} {'rank_err':>9}")
    results = {}
    for mode, kw in (("hemt", {"weights": [1.0, 0.4]}),
                     ("even", {}),
                     ("homt-16", {"n_tasks": 16}),
                     ("homt-64", {"n_tasks": 64})):
        job = PageRankJob(src, dst, N, nodes(), mode=mode.split("-")[0], **kw)
        ranks = job.run(ITERS)
        err = float(np.max(np.abs(ranks - ref)))
        owned = np.bincount(job.owner, minlength=2)
        results[mode] = job.total_time()
        print(f"{mode:<12} {job.total_time():9.1f} "
              f"{str(owned.tolist()):>18} {err:9.1e}")

    gain = (results["even"] - results["hemt"]) / results["even"] * 100
    print(f"\nHeMT (Algorithm 1 skewed shuffle) vs default even hash: "
          f"{gain:.1f}% faster; HomT-64 pays "
          f"{results['homt-64'] / results['hemt']:.1f}x (overhead regime)")


if __name__ == "__main__":
    main()
