"""HeMT-serve: continuous batching across heterogeneous replicas.

Serves a reduced decoder with REAL token generation on three replicas
(one throttled to 0.4x — the paper's burstable/contended host). The
HeMTBatcher sizes per-replica request batches with the §5.1 AR(1)
estimator; compare against even dispatch.

  PYTHONPATH=src python examples/serve_hemt.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.model import init_decode_state, init_params
from repro.runtime.serve_loop import HeMTBatcher, make_serve_step

GEN_LEN = 12
REQUESTS = 28
ROUNDS = 6
SPEEDS = {"rep0": 1.0, "rep1": 1.0, "rep2": 0.4}
BASE_TOKS_PER_S = 200.0


def run(mode: str) -> float:
    cfg = get_reduced("granite-3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    serve_step = jax.jit(make_serve_step(cfg))
    batcher = HeMTBatcher(list(SPEEDS), mode=mode, min_share=1)

    total = 0.0
    for rnd in range(ROUNDS):
        shares = batcher.dispatch(REQUESTS)
        finish = {}
        for name, speed in SPEEDS.items():
            b = shares[name]
            if b == 0:
                finish[name] = 0.0
                continue
            state = init_decode_state(cfg, b, GEN_LEN + 1)
            tok = jnp.ones((b,), jnp.int32)
            outs = []
            for _ in range(GEN_LEN):
                tok, _lg, state = serve_step(params, state, tok)
                outs.append(np.asarray(tok))
            assert np.isfinite(np.stack(outs)).all()
            tokens = b * GEN_LEN
            finish[name] = tokens / (speed * BASE_TOKS_PER_S)
            batcher.observe(name, tokens, finish[name])
        span = max(finish.values())
        total += span
        print(f"  round {rnd}: shares={shares} batch_makespan={span:.2f}s")
    return total


def main() -> None:
    print("== even dispatch (HomT-like) ==")
    t_even = run("even")
    print("== HeMT dispatch ==")
    t_hemt = run("hemt")
    print(f"\ntotal serving time: even={t_even:.2f}s hemt={t_hemt:.2f}s "
          f"({(t_even - t_hemt) / t_even * 100:.1f}% faster once replica "
          f"speeds are learned)")


if __name__ == "__main__":
    main()
